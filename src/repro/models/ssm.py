"""Mamba selective-state-space block (Jamba's sequence mixer).

Two scan implementations sharing one parameterisation:

* ``selective_scan_assoc`` — ``jax.lax.associative_scan`` over time
  (parallel in sequence; the train/prefill path).  Elements are the
  (decay, increment) pairs of the linear recurrence
  ``h_t = exp(dt_t·A)·h_{t-1} + dt_t·B_t·x_t``.
* ``selective_scan_seq`` — ``lax.scan`` step form carrying (B, D_in, N)
  state; the decode path and the numerical oracle.

The Pallas TPU kernel (``repro.kernels.ssd_scan``) implements the chunked
form with the state carried in VMEM scratch across sequential grid steps;
models switch via ``use_kernels``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import BF16, F32, ParamBuilder

Constrain = Callable[..., jax.Array]
DT_RANK_MIN = 8


class SSMState(NamedTuple):
    h: jax.Array  # (B, D_in, N) f32


def dt_rank(cfg: ArchConfig) -> int:
    return max(DT_RANK_MIN, cfg.d_model // 16)


def init_mamba(pb: ParamBuilder, path: str, cfg: ArchConfig,
               stack: int | None = None) -> None:
    mb = cfg.mamba
    D = cfg.d_model
    Din = mb.expand * D
    N = mb.d_state
    R = dt_rank(cfg)
    pb.weight(f"{path}/w_in", (D, 2 * Din), ("d_model", "d_inner"),
              stack=stack)
    pb.weight(f"{path}/w_conv", (mb.d_conv, Din), ("d_conv", "d_inner"),
              scale=0.5, stack=stack)
    pb.weight(f"{path}/w_x", (Din, R + 2 * N), ("d_inner", "d_state"),
              stack=stack)
    pb.weight(f"{path}/w_dt", (R, Din), ("d_state", "d_inner"),
              stack=stack)
    # A is initialised to -[1..N] per channel (S4D-real init).
    pb.zeros(f"{path}/a_log", (Din, N), ("d_inner", "d_state"),
             dtype=F32, stack=stack)
    pb.ones(f"{path}/d_skip", (Din,), ("d_inner",), dtype=F32, stack=stack)
    pb.weight(f"{path}/w_out", (Din, D), ("d_inner", "d_model"),
              stack=stack)


def _discretize(x, dt, A, Bmat):
    """dA (B,S,Din,N) decay, dBx increment."""
    dA = jnp.exp(dt[..., None] * A)                       # A < 0
    dBx = (dt * x)[..., None] * Bmat[:, :, None, :]
    return dA, dBx


def selective_scan_assoc(x, dt, A, Bmat, Cmat):
    """x,dt (B,S,Din); A (Din,N); B,C (B,S,N) → y (B,S,Din).  Parallel in
    S via associative scan over (decay, state) pairs."""
    dA, dBx = _discretize(x.astype(F32), dt.astype(F32), A,
                          Bmat.astype(F32))

    def combine(a, b):
        (da, xa), (db, xb) = a, b
        return da * db, xb + db * xa

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cmat.astype(F32))
    return y


def selective_scan_chunked(x, dt, A, Bmat, Cmat, chunk: int = 256):
    """Chunked form: sequential ``lax.scan`` over chunks carrying the
    (B, Din, N) state, associative scan within each chunk.

    Motivation (measured, jamba train_4k): the full-sequence associative
    scan materialises (B,S,Din,N) f32 pairs — ~550 GB per tensor at
    global batch, and the scan backward keeps O(log S) of them alive →
    221 GiB/device.  Chunking bounds the working set to
    (B,chunk,Din,N) per step and the rematerialised chunk body saves only
    the (B,Din,N) carry."""
    B_, S, Din = x.shape
    N = A.shape[-1]
    if S % chunk or S <= chunk:
        return selective_scan_assoc(x, dt, A, Bmat, Cmat)
    nc = S // chunk

    def to_chunks(t):
        return t.reshape(B_, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x.astype(F32)), to_chunks(dt.astype(F32)),
          to_chunks(Bmat.astype(F32)), to_chunks(Cmat.astype(F32)))

    def body(h, inp):
        xc, dtc, bc, cc = inp
        dA, dBx = _discretize(xc, dtc, A, bc)

        def combine(a, b):
            (da, xa), (db, xb) = a, b
            return da * db, xb + db * xa

        da_c, h_c = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        h_full = h_c + da_c * h[:, None]      # carry-in contribution
        y = jnp.einsum("bsdn,bsn->bsd", h_full, cc)
        return h_full[:, -1], y

    h0 = jnp.zeros((B_, Din, N), F32)
    _, ys = jax.lax.scan(jax.checkpoint(body), h0, xs)
    return ys.swapaxes(0, 1).reshape(B_, S, Din)


def selective_scan_seq(x, dt, A, Bmat, Cmat, h0=None):
    """Step-form oracle; also the decode path (S may be 1).  Returns
    (y, h_final)."""
    B_, S, Din = x.shape
    N = A.shape[-1]
    h0 = h0 if h0 is not None else jnp.zeros((B_, Din, N), F32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt[..., None] * A)
        h = dA * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (x.astype(F32).swapaxes(0, 1), dt.astype(F32).swapaxes(0, 1),
          Bmat.astype(F32).swapaxes(0, 1), Cmat.astype(F32).swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h


def _causal_conv(x: jax.Array, w: jax.Array,
                 carry: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d; ``carry`` ((B, k-1, Din)) for decode."""
    k = w.shape[0]
    if carry is not None:
        x = jnp.concatenate([carry, x], axis=1)
        pad = 0
    else:
        pad = k - 1
    xp = jnp.pad(x, ((0, 0), (pad, 0), (0, 0))) if pad else x
    out = sum(xp[:, i:i + x.shape[1] - (0 if pad else k - 1)] * w[i]
              for i in range(k))
    return out


def mamba_block(x: jax.Array, p: dict, cfg: ArchConfig,
                constrain: Constrain,
                state: Optional[SSMState] = None,
                conv_carry: jax.Array | None = None,
                use_kernels: bool = False):
    """(B,S,D) → (B,S,D).  With ``state`` given, runs the step form and
    returns (y, new_state, new_conv_carry)."""
    mb = cfg.mamba
    D = cfg.d_model
    Din = mb.expand * D
    R = dt_rank(cfg)
    N = mb.d_state

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xz = constrain(xz, ("batch", "seq", "d_inner"), "xz")
    xin, z = xz[..., :Din], xz[..., Din:]

    new_carry = None
    if state is not None:
        k = mb.d_conv
        cc = (conv_carry if conv_carry is not None
              else jnp.zeros((x.shape[0], k - 1, Din), x.dtype))
        xc = _causal_conv(xin, p["w_conv"], cc)
        new_carry = jnp.concatenate([cc, xin], axis=1)[:, -(k - 1):]
    else:
        xc = _causal_conv(xin, p["w_conv"])
    xc = jax.nn.silu(xc.astype(F32)).astype(x.dtype)

    proj = jnp.einsum("bse,er->bsr", xc, p["w_x"])
    dt_r, Bmat, Cmat = (proj[..., :R], proj[..., R:R + N],
                        proj[..., R + N:])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["w_dt"]).astype(F32))
    A = -jnp.exp(p["a_log"]) - jnp.arange(1, N + 1, dtype=F32)[None, :]

    if state is not None:
        y, h = selective_scan_seq(xc, dt, A, Bmat, Cmat, state.h)
        new_state = SSMState(h)
    else:
        if use_kernels:
            from ..kernels.ssd_scan import ops as ssd_ops
            y = ssd_ops.ssd_scan(xc, dt, A, Bmat, Cmat, chunk=mb.chunk)
        else:
            y = selective_scan_chunked(xc, dt, A, Bmat, Cmat,
                                       chunk=mb.chunk)
        new_state = None
    y = y + xc.astype(F32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "d_inner"), "scan_out")

    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if state is not None:
        return out, new_state, new_carry
    return out
