"""Attention family: GQA (with causal / sliding-window masks), cross
attention (VLM image layers), and DeepSeek-style MLA with the absorbed
decode form over the latent KV cache.

All functions take a ``constrain`` callable — the ShardingPlan's buffer
sites — so the HIDA plan, not the model, owns layout decisions.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import BF16, F32, ParamBuilder, apply_rope, rope_angles

Constrain = Callable[..., jax.Array]
_NEG = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KVH, Dh)  or MLA: (B, S_max, kv_lora+rope)
    v: Optional[jax.Array]
    #: tokens already cached.  Scalar int32 for lock-step decode (all
    #: batch rows at one position — the training/smoke path), or a
    #: per-slot ``(B,)`` int32 vector for the continuous-batching server,
    #: where every slot advances independently.  The rank is static under
    #: jit, so the two layouts trace to different (cached) programs.
    pos: jax.Array


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def init_gqa(pb: ParamBuilder, path: str, cfg: ArchConfig,
             stack: int | None = None) -> None:
    D, H, KV, Dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    pb.weight(f"{path}/w_q", (D, H, Dh), ("d_model", "heads", "d_head"),
              stack=stack)
    pb.weight(f"{path}/w_kv", (D, 2, KV, Dh),
              ("d_model", "two", "kv_heads", "d_head"), stack=stack)
    pb.weight(f"{path}/w_o", (H, Dh, D), ("heads", "d_head", "d_model"),
              stack=stack)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
          ) -> jax.Array:
    """q (B,Sq,H,Dh), k/v (B,Skv,KVH,Dh) with GQA head grouping."""
    B, Sq, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(F32)
    scores = scores / math.sqrt(Dh)
    if mask is not None:
        scores = jnp.where(mask, scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(q.dtype), v)
    return ctx.reshape(B, Sq, H, v.shape[-1])


#: switch to the memory-linear chunked path above this many score elements
#: (the materialised (B,H,Sq,Skv) f32 tensor is what blows HBM otherwise)
_FLASH_THRESHOLD = 1 << 21
_Q_BLOCK = 256
_KV_BLOCK = 1024


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        q_block: int = _Q_BLOCK,
                        kv_block: int = _KV_BLOCK,
                        scale: float | None = None) -> jax.Array:
    """Online-softmax chunked attention (FlashAttention dataflow in pure
    jnp): O(Sq·Dh) memory instead of O(Sq·Skv).  Doubles as the oracle for
    the Pallas TPU kernel.  GQA grouping handled natively.

    Both scan bodies are rematerialised so the backward pass never holds
    more than one (q_block × kv_block) probability tile per head group.
    """
    B, Sq, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    Skv = k.shape[1]
    Dv = v.shape[-1]          # MLA: value dim ≠ qk dim
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    nq, nk = Sq // q_block, Skv // kv_block
    if Sq % q_block or Skv % kv_block:
        return _sdpa(q, k, v,
                     causal_mask(Sq, Skv, window) if causal else None)
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)

    qb = q.reshape(B, nq, q_block, KVH, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, kv_block, KVH, Dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_block, KVH, Dv).transpose(1, 0, 3, 2, 4)

    def q_body(_, inputs):
        qblk, qi = inputs

        # qblk is closed over, NOT carried: carrying it through the kv
        # scan makes the backward save a copy per kv iteration (measured:
        # tens of GiB of stacked q tiles on the 128-head MLA configs).
        def kv_body(carry, kv_inputs):
            m, l, acc = carry
            kblk, vblk, ki = kv_inputs
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(F32),
                           kblk.astype(F32)) * scale
            qpos = qi * q_block + jnp.arange(q_block)
            kpos = ki * kv_block + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(F32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, KVH, G, q_block), -jnp.inf, F32)
        l0 = jnp.zeros((B, KVH, G, q_block), F32)
        a0 = jnp.zeros((B, KVH, G, q_block, Dv), F32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_body), (m0, l0, a0),
            (kb, vb, jnp.arange(nk)))
        y = acc / jnp.maximum(l, 1e-30)[..., None]
        # Stack per-block outputs in the storage dtype: the f32 stacked
        # ys of a 128-head MLA layer is 3 GiB/device otherwise.
        return None, y.astype(q.dtype)

    _, ys = jax.lax.scan(jax.checkpoint(q_body), None,
                         (qb, jnp.arange(nq)))
    # ys: (nq, B, KVH, G, q_block, Dv)
    out = ys.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def causal_mask(Sq: int, Skv: int, window: int | None = None,
                q_offset: int = 0) -> jax.Array:
    """(1,1,1,Sq,Skv) boolean mask; ``window`` adds the SWA band."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None, None]


def decode_mask(Skv: int, pos: jax.Array, window: int | None = None
                ) -> jax.Array:
    """Single-token decode mask at position ``pos``: ``(1,1,1,1,Skv)``
    for scalar ``pos``, ``(B,1,1,1,Skv)`` for per-slot ``(B,)`` ``pos``
    (each slot attends only to its own prefix, so stale cache rows from
    a previous slot occupant are masked to exact-zero probability)."""
    kpos = jnp.arange(Skv)
    if pos.ndim:
        m = kpos[None, :] <= pos[:, None]
        if window is not None:
            m = m & (kpos[None, :] > pos[:, None] - window)
        return m[:, None, None, None, :]
    m = kpos <= pos
    if window is not None:
        m = m & (kpos > pos - window)
    return m[None, None, None, None, :]


def gqa_attention(x: jax.Array, p: dict, cfg: ArchConfig,
                  positions: jax.Array, constrain: Constrain,
                  cache: KVCache | None = None,
                  kv_x: jax.Array | None = None,
                  causal: bool = True,
                  use_kernels: bool = False,
                  ) -> tuple[jax.Array, KVCache | None]:
    """Self- or cross-attention.  ``cache`` implies single-step decode;
    ``kv_x`` switches to cross-attention over a context stream."""
    Dh = cfg.resolved_head_dim
    rot_dim = int(Dh * cfg.rope_pct) & ~1

    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    src = kv_x if kv_x is not None else x
    kv = jnp.einsum("bsd,dghk->bsghk", src, p["w_kv"])
    k, v = kv[:, :, 0], kv[:, :, 1]
    q = constrain(q, ("batch", "seq", "heads", "d_head"), "q")
    k = constrain(k, ("batch", "kv_seq", "kv_heads", "d_head"), "k")
    v = constrain(v, ("batch", "kv_seq", "kv_heads", "d_head"), "v")

    if kv_x is None and rot_dim > 0:
        cos, sin = rope_angles(positions, rot_dim)
        q = apply_rope(q, cos, sin, rot_dim)
        kv_pos = positions if cache is None else positions
        kcos, ksin = (cos, sin)
        k = apply_rope(k, kcos, ksin, rot_dim)

    new_cache = None
    if cache is not None:
        if cache.pos.ndim:
            # Per-slot decode (continuous batching): each row scatters
            # its single new token at its own position.  S must be 1.
            rows = jnp.arange(x.shape[0])
            k_all = cache.k.at[rows, cache.pos].set(k[:, 0])
            v_all = cache.v.at[rows, cache.pos].set(v[:, 0])
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache.k, k, (0, cache.pos, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache.v, v, (0, cache.pos, 0, 0))
        new_cache = KVCache(k_all, v_all, cache.pos + x.shape[1])
        mask = decode_mask(k_all.shape[1], cache.pos, cfg.attn_window)
        ctx = _sdpa(q, k_all, v_all, mask)
    else:
        is_causal = causal and kv_x is None
        if use_kernels:
            from ..kernels.flash_attention import ops as fa_ops
            ctx = fa_ops.mha(q, k, v, causal=is_causal,
                             window=cfg.attn_window,
                             q_block=min(128, q.shape[1]),
                             kv_block=min(128, k.shape[1]))
        elif q.shape[1] * k.shape[1] > _FLASH_THRESHOLD:
            ctx = flash_attention_jnp(q, k, v, causal=is_causal,
                                      window=cfg.attn_window)
        else:
            mask = (causal_mask(x.shape[1], k.shape[1], cfg.attn_window)
                    if is_causal else None)
            ctx = _sdpa(q, k, v, mask)

    ctx = constrain(ctx, ("batch", "seq", "heads", "d_head"), "attn_ctx")
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["w_o"])
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek V2/V3)
# --------------------------------------------------------------------------

def init_mla(pb: ParamBuilder, path: str, cfg: ArchConfig,
             stack: int | None = None) -> None:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    pb.weight(f"{path}/w_q_a", (D, m.q_lora), ("d_model", "q_lora"),
              stack=stack)
    pb.weight(f"{path}/w_q_b", (m.q_lora, H, m.nope_dim + m.rope_dim),
              ("q_lora", "heads", "d_head"), stack=stack)
    pb.weight(f"{path}/w_kv_a", (D, m.kv_lora + m.rope_dim),
              ("d_model", "kv_lora"), stack=stack)
    pb.weight(f"{path}/w_uk", (H, m.kv_lora, m.nope_dim),
              ("heads", "kv_lora", "d_head"), stack=stack)
    pb.weight(f"{path}/w_uv", (H, m.kv_lora, m.v_dim),
              ("heads", "kv_lora", "d_head"), stack=stack)
    pb.weight(f"{path}/w_o", (H, m.v_dim, D),
              ("heads", "d_head", "d_model"), stack=stack)


def mla_attention(x: jax.Array, p: dict, cfg: ArchConfig,
                  positions: jax.Array, constrain: Constrain,
                  cache: KVCache | None = None,
                  ) -> tuple[jax.Array, KVCache | None]:
    """MLA with the latent cache: prefill/train uses the materialised
    per-head K/V; decode uses the *absorbed* form (queries projected into
    latent space so the cache stays (kv_lora+rope) per token)."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads

    qa = jnp.einsum("bsd,dr->bsr", x, p["w_q_a"])
    q = jnp.einsum("bsr,rhk->bshk", qa, p["w_q_b"])
    q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_kv_a"])
    q_nope = constrain(q_nope, ("batch", "seq", "heads", "d_head"), "q")
    ckv_full = constrain(ckv_full, ("batch", "kv_seq", "kv_lora"), "c_kv")

    cos, sin = rope_angles(positions, m.rope_dim)
    q_pe = apply_rope(q_pe, cos, sin, m.rope_dim)
    k_pe = apply_rope(ckv_full[:, :, None, m.kv_lora:], cos, sin,
                      m.rope_dim)[:, :, 0]
    ckv = jnp.concatenate([ckv_full[..., :m.kv_lora], k_pe], axis=-1)

    new_cache = None
    if cache is not None:
        if cache.pos.ndim:
            lat = cache.k.at[jnp.arange(B), cache.pos].set(ckv[:, 0])
        else:
            lat = jax.lax.dynamic_update_slice(cache.k, ckv,
                                               (0, cache.pos, 0))
        new_cache = KVCache(lat, None, cache.pos + S)
        c_nope, c_pe = lat[..., :m.kv_lora], lat[..., m.kv_lora:]
        # Absorbed: q_lat[h] = q_nope[h] @ W_uk[h]  (B,S,H,kv_lora).
        # f32 accumulation throughout so the absorbed and materialised
        # forms agree (MXU accumulates f32 natively).
        q_lat = jnp.einsum("bshk,hrk->bshr", q_nope, p["w_uk"],
                           preferred_element_type=F32)
        scores = (jnp.einsum("bshr,btr->bhst", q_lat,
                             c_nope.astype(F32))
                  + jnp.einsum("bshk,btk->bhst", q_pe, c_pe,
                               preferred_element_type=F32))
        scores = scores / math.sqrt(m.nope_dim + m.rope_dim)
        kpos = jnp.arange(lat.shape[1])[None, None, None, :]
        cpos = (cache.pos[:, None, None, None] if cache.pos.ndim
                else cache.pos)
        scores = jnp.where(kpos <= cpos, scores, _NEG)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs,
                             c_nope.astype(F32))
        ctx = jnp.einsum("bshr,hrv->bshv", ctx_lat,
                         p["w_uv"].astype(F32)).astype(x.dtype)
    else:
        # NOTE (§Perf P3.5, refuted & reverted): running the *absorbed*
        # form here (flash over the shared latent cache, KVH=1, Dqk=640)
        # measured WORSE — 106→184 GiB/dev, coll 190→412 GiB — because
        # per-head latent queries (H·640) + latent contexts (H·512)
        # outweigh the per-head k/v (H·320) they replace.  The
        # materialised per-head flash below is the better training form.
        k_nope = jnp.einsum("bsr,hrk->bshk", ckv[..., :m.kv_lora],
                            p["w_uk"], preferred_element_type=F32)
        v = jnp.einsum("bsr,hrv->bshv", ckv[..., :m.kv_lora],
                       p["w_uv"], preferred_element_type=F32)
        if S * S > _FLASH_THRESHOLD:
            # Concat the nope/rope halves into one effective q/k — MLA
            # reduces to standard attention with Dv ≠ Dqk, which the
            # chunked path supports.
            BF = x.dtype
            q_eff = jnp.concatenate([q_nope.astype(BF),
                                     q_pe.astype(BF)], axis=-1)
            k_pe_h = jnp.broadcast_to(
                ckv[:, :, None, m.kv_lora:],
                (B, S, H, m.rope_dim)).astype(BF)
            k_eff = jnp.concatenate([k_nope.astype(BF), k_pe_h], axis=-1)
            ctx = flash_attention_jnp(q_eff, k_eff, v.astype(BF),
                                      causal=True)
        else:
            scores = (jnp.einsum("bshk,bthk->bhst", q_nope.astype(F32),
                                 k_nope)
                      + jnp.einsum("bshk,btk->bhst", q_pe,
                                   ckv[..., m.kv_lora:],
                                   preferred_element_type=F32))
            scores = scores / math.sqrt(m.nope_dim + m.rope_dim)
            qpos = jnp.arange(S)[:, None]
            tpos = jnp.arange(S)[None, :]
            scores = jnp.where((tpos <= qpos)[None, None], scores, _NEG)
            probs = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhst,bthv->bshv", probs, v).astype(x.dtype)

    ctx = constrain(ctx, ("batch", "seq", "heads", "d_head"), "attn_ctx")
    out = jnp.einsum("bshv,hvd->bsd", ctx, p["w_o"])
    return out, new_cache
