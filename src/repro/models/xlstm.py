"""xLSTM blocks [arXiv:2405.04517].

* **mLSTM** — matrix-memory LSTM ≈ gated linear attention.  Parallel
  chunkwise form for train/prefill (intra-chunk quadratic + inter-chunk
  state recurrence), step form for decode.  The sequence dim is
  chunk-parallelizable, so HIDA may shard it.
* **sLSTM** — scalar-memory LSTM with exponential gating and a stabiliser
  state.  The recurrence feeds h_{t-1} back through the gate
  pre-activations, so it is *sequence-sequential* (``lax.scan``); the
  graph marks ``seq`` non-shardable for this node — the paper's ∅
  permutation-map entry.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import BF16, F32, ParamBuilder

Constrain = Callable[..., jax.Array]


class MLSTMState(NamedTuple):
    C: jax.Array   # (B,H,Dh,Dh) matrix memory
    n: jax.Array   # (B,H,Dh)    normaliser
    m: jax.Array   # (B,H)       stabiliser


class SLSTMState(NamedTuple):
    c: jax.Array   # (B,D)
    n: jax.Array   # (B,D)
    h: jax.Array   # (B,D)
    m: jax.Array   # (B,D)


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def init_mlstm(pb: ParamBuilder, path: str, cfg: ArchConfig,
               stack: int | None = None) -> None:
    x = cfg.xlstm
    D = cfg.d_model
    Din = x.proj_factor_mlstm * D
    pb.weight(f"{path}/w_up", (D, 2 * Din), ("d_model", "d_inner"),
              stack=stack)
    pb.weight(f"{path}/w_qkv", (Din, 3, Din), ("d_inner", "three",
                                               "d_inner2"), stack=stack)
    pb.weight(f"{path}/w_if", (Din, 2, cfg.n_heads),
              ("d_inner", "two", "heads"), scale=0.01, stack=stack)
    pb.weight(f"{path}/w_down", (Din, D), ("d_inner", "d_model"),
              stack=stack)


def _mlstm_parallel(q, k, v, i_pre, f_pre):
    """Stabilised parallel form over the full sequence (quadratic): used
    per chunk.  q,k,v (B,S,H,Dh); i_pre,f_pre (B,S,H)."""
    B, S, H, Dh = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(F32))           # (B,S,H)
    F_cum = jnp.cumsum(logf, axis=1)
    # D[s,t] = sum_{r=t+1..s} logf_r + i_t  for t<=s
    dmat = (F_cum[:, :, None] - F_cum[:, None, :]
            + i_pre.astype(F32)[:, None, :, :])            # (B,S,T,H)
    tpos = jnp.arange(S)
    causal = tpos[None, :, None] >= tpos[None, None, :]
    dmat = jnp.where(causal[..., None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)               # (B,S,1,H)
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bshd,bthd->bsth", q.astype(F32),
                        k.astype(F32)) / (Dh ** 0.5)
    w = scores * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0]))
    y = jnp.einsum("bsth,bthd->bshd", w, v.astype(F32))
    return y / (norm[..., None] + 1e-6)


def mlstm_block(x: jax.Array, p: dict, cfg: ArchConfig,
                constrain: Constrain,
                state: Optional[MLSTMState] = None,
                use_kernels: bool = False):
    xc = cfg.xlstm
    D = cfg.d_model
    Din = xc.proj_factor_mlstm * D
    H = cfg.n_heads
    Dh = Din // H
    B, S, _ = x.shape

    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    up = constrain(up, ("batch", "seq", "d_inner"), "up")
    xin, z = up[..., :Din], up[..., Din:]
    qkv = jnp.einsum("bse,egf->bsgf", xin, p["w_qkv"])
    q, k, v = (qkv[:, :, i].reshape(B, S, H, Dh) for i in range(3))
    if_pre = jnp.einsum("bse,egh->bsgh", xin, p["w_if"])
    i_pre, f_pre = if_pre[:, :, 0], if_pre[:, :, 1]

    if state is not None:
        # Step form: exponential-gated rank-1 update of the matrix memory.
        logf = jax.nn.log_sigmoid(f_pre.astype(F32))[:, 0]      # (B,H)
        i_t = i_pre.astype(F32)[:, 0]
        m_new = jnp.maximum(logf + state.m, i_t)
        fg = jnp.exp(logf + state.m - m_new)[..., None]
        ig = jnp.exp(i_t - m_new)[..., None]
        kt = k.astype(F32)[:, 0] / (Dh ** 0.5)
        vt = v.astype(F32)[:, 0]
        C = fg[..., None] * state.C + ig[..., None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fg * state.n + ig * kt
        qt = q.astype(F32)[:, 0]
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))[..., None]
        y = (num / (den + 1e-6))[:, None].reshape(B, 1, Din)
        new_state = MLSTMState(C, n, m_new)
    elif use_kernels:
        from ..kernels.mlstm_chunk import ops as mlstm_ops
        y = mlstm_ops.mlstm_chunk(q, k, v, i_pre, f_pre,
                                  chunk=xc.chunk).reshape(B, S, Din)
        new_state = None
    else:
        # Chunkless parallel reference (quadratic in S) for short
        # sequences; chunked execution happens in the Pallas kernel.
        y = _mlstm_parallel(q, k, v, i_pre, f_pre).reshape(B, S, Din)
        new_state = None

    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "d_inner"), "scan_out")
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    if state is not None:
        return out, new_state
    return out


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def init_slstm(pb: ParamBuilder, path: str, cfg: ArchConfig,
               stack: int | None = None) -> None:
    x = cfg.xlstm
    D = cfg.d_model
    pb.weight(f"{path}/w_gates", (D, 4, D), ("d_model", "four", "d_inner"),
              stack=stack)
    pb.weight(f"{path}/r_gates", (D, 4, D), ("d_model", "four", "d_inner"),
              scale=0.01, stack=stack)
    if x.d_ff_slstm:
        pb.weight(f"{path}/w_ffn_in", (D, 2, x.d_ff_slstm),
                  ("d_model", "two", "d_ff"), stack=stack)
        pb.weight(f"{path}/w_ffn_out", (x.d_ff_slstm, D),
                  ("d_ff", "d_model"), stack=stack)


def _slstm_step(p: dict, state: SLSTMState, x_t: jax.Array) -> tuple:
    """One exponential-gated sLSTM step; x_t (B,D)."""
    pre = (jnp.einsum("bd,dge->bge", x_t.astype(F32), p["w_gates"].astype(F32))
           + jnp.einsum("bd,dge->bge", state.h, p["r_gates"].astype(F32)))
    i_p, f_p, z_p, o_p = (pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3])
    logf = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(logf + state.m, i_p)
    ig = jnp.exp(i_p - m_new)
    fg = jnp.exp(logf + state.m - m_new)
    z = jnp.tanh(z_p)
    c = fg * state.c + ig * z
    n = fg * state.n + ig
    h = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h, m_new), h


def slstm_block(x: jax.Array, p: dict, cfg: ArchConfig,
                constrain: Constrain,
                state: Optional[SLSTMState] = None):
    B, S, D = x.shape
    s0 = state if state is not None else SLSTMState(
        *(jnp.zeros((B, D), F32) for _ in range(3)),
        jnp.full((B, D), -1e30, F32))

    def step(carry, x_t):
        new, h = _slstm_step(p, carry, x_t)
        return new, h

    final, hs = jax.lax.scan(step, s0, x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "d_model"), "scan_out")

    if "w_ffn_in" in p:
        h = jnp.einsum("bsd,dgf->bsgf", y, p["w_ffn_in"])
        act = jax.nn.silu(h[..., 0, :].astype(F32)).astype(x.dtype) \
            * h[..., 1, :]
        y = jnp.einsum("bsf,fd->bsd", act, p["w_ffn_out"])
    if state is not None:
        return y, final
    return y
