"""Decoder LM assembly for all assigned architectures.

A model is assembled from the config's layer pattern: homogeneous
repeated super-blocks are executed with ``lax.scan`` over stacked
parameters (keeps HLO size O(pattern), not O(n_layers) — essential for the
60-layer dry-runs), prefix layers run unrolled.  Sharding is applied only
through the ShardingPlan's buffer sites; the model never names a mesh
axis.

Entry points:

* ``loss_fn(params, batch)``    — training loss (+ MoE aux, MTP).
* ``prefill(params, batch)``    — full-sequence forward; returns logits
  and initialised caches.
* ``decode_step(params, batch, caches)`` — one-token step with KV / SSM /
  xLSTM state caches.
* ``init_caches(B, S_max)``     — abstract-friendly cache pytree.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (KVCache, gqa_attention, init_gqa, init_mla,
                        mla_attention)
from .layers import (BF16, F32, ParamBuilder, apply_norm, cross_entropy,
                     init_mlp, init_norm, mlp)
from .moe import MoEAux, init_moe, moe_ffn
from .ssm import SSMState, init_mamba, mamba_block
from .xlstm import (MLSTMState, SLSTMState, init_mlstm, init_slstm,
                    mlstm_block, slstm_block)

AUX_LB_WEIGHT = 0.01
AUX_Z_WEIGHT = 1e-3
MTP_WEIGHT = 0.3


def _noop_constrain(x, dims, site=None):
    return x


@dataclass
class LM:
    cfg: ArchConfig
    plan: Any = None              # ShardingPlan | None
    mesh: Any = None              # concrete jax Mesh (shard_map EP path)
    use_kernels: bool = False
    remat: str = "full"           # none | full | dots

    # -- helpers ---------------------------------------------------------------
    @property
    def constrain(self) -> Callable:
        if self.plan is None:
            return _noop_constrain
        return self.plan.constrain

    def _groups(self):
        return self.cfg.layer_groups()

    def _ep(self):
        """Expert-parallel routing hint: (batch_axes, expert_axes,
        seq_axes, mesh) — the explicit all_to_all dispatch path.  The
        concrete mesh must be captured here: inside scan/checkpoint
        tracing the ambient-mesh context is abstract."""
        if self.plan is None or self.mesh is None:
            return None
        eaxes = tuple(self.plan.rules.get("experts", ()))
        if not eaxes:
            return None
        baxes = tuple(self.plan.rules.get("batch", ()))
        saxes = tuple(a for a in self.plan.rules.get("seq", ())
                      if a not in baxes)
        tp = self.plan.meta.get("moe_tp")
        return (baxes, eaxes, saxes, self.mesh, tp)

    # -- init --------------------------------------------------------------------
    def init(self, rng: jax.Array | None,
             abstract: bool = False) -> tuple[dict, dict]:
        """Returns (params, dims) — dims mirrors params with logical axis
        names for plan-driven sharding.  ``abstract=True`` returns
        ShapeDtypeStructs (dry-run: zero allocation)."""
        cfg = self.cfg
        pb = ParamBuilder(rng, abstract=abstract)
        if cfg.frontend != "audio_frames":
            pb.weight("embed", (cfg.vocab, cfg.d_model),
                      ("vocab", "d_model"), scale=0.02)
        for gi, (pattern, repeats) in enumerate(self._groups()):
            stack = repeats if repeats > 1 else None
            base = f"group{gi}"
            for j, (mix, ffn) in enumerate(pattern):
                pfx = f"{base}/b{j}"
                init_norm(pb, f"{pfx}/norm1", cfg.norm, cfg.d_model,
                          stack=stack)
                if mix in ("attn", "xattn"):
                    if cfg.mla is not None:
                        init_mla(pb, f"{pfx}/mix", cfg, stack=stack)
                    else:
                        init_gqa(pb, f"{pfx}/mix", cfg, stack=stack)
                elif mix == "mamba":
                    init_mamba(pb, f"{pfx}/mix", cfg, stack=stack)
                elif mix == "mlstm":
                    init_mlstm(pb, f"{pfx}/mix", cfg, stack=stack)
                elif mix == "slstm":
                    init_slstm(pb, f"{pfx}/mix", cfg, stack=stack)
                if ffn != "none":
                    init_norm(pb, f"{pfx}/norm2", cfg.norm, cfg.d_model,
                              stack=stack)
                if ffn == "dense":
                    d_ff = cfg.dense_d_ff or cfg.d_ff
                    init_mlp(pb, f"{pfx}/ffn", cfg.d_model, d_ff,
                             stack=stack)
                elif ffn == "moe":
                    init_moe(pb, f"{pfx}/ffn", cfg, stack=stack)
        init_norm(pb, "final_norm", cfg.norm, cfg.d_model)
        if not cfg.tie_embeddings:
            pb.weight("head", (cfg.d_model, cfg.vocab),
                      ("d_model", "vocab"), scale=0.02)
        if cfg.mtp:
            pb.weight("mtp/proj", (2 * cfg.d_model, cfg.d_model),
                      ("d_model2", "d_model"))
            init_norm(pb, "mtp/norm1", cfg.norm, cfg.d_model)
            init_gqa(pb, "mtp/mix", cfg)
            init_norm(pb, "mtp/norm2", cfg.norm, cfg.d_model)
            init_mlp(pb, "mtp/ffn", cfg.d_model,
                     cfg.dense_d_ff or cfg.d_ff)
        return pb.params, pb.dims

    # -- one block ----------------------------------------------------------------
    def _block(self, resid, bp, mix, ffn, positions, img, cache=None):
        cfg = self.cfg
        c = self.constrain
        aux = MoEAux(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))
        x = apply_norm(cfg.norm, resid, bp["norm1"])
        new_cache = cache
        if mix in ("attn", "xattn"):
            kv_x = img if mix == "xattn" else None
            if cfg.mla is not None:
                out, kvc = mla_attention(x, bp["mix"], cfg, positions, c,
                                         cache=cache)
            else:
                out, kvc = gqa_attention(
                    x, bp["mix"], cfg, positions, c, cache=cache,
                    kv_x=kv_x,
                    use_kernels=self.use_kernels and cache is None)
            new_cache = kvc if cache is not None else None
        elif mix == "mamba":
            if cache is not None:
                state, carry = cache
                out, state, carry = mamba_block(
                    x, bp["mix"], cfg, c, state=state, conv_carry=carry)
                new_cache = (state, carry)
            else:
                out = mamba_block(x, bp["mix"], cfg, c,
                                  use_kernels=self.use_kernels)
        elif mix == "mlstm":
            if cache is not None:
                out, new_cache = mlstm_block(x, bp["mix"], cfg, c,
                                             state=cache)
            else:
                out = mlstm_block(x, bp["mix"], cfg, c,
                                  use_kernels=self.use_kernels)
        elif mix == "slstm":
            if cache is not None:
                out, new_cache = slstm_block(x, bp["mix"], cfg, c,
                                             state=cache)
            else:
                out = slstm_block(x, bp["mix"], cfg, c)
        resid = resid + out
        resid = c(resid, ("batch", "seq", "d_model"), "residual")

        if ffn == "dense":
            x2 = apply_norm(cfg.norm, resid, bp["norm2"])
            resid = resid + mlp(x2, bp["ffn"], c)
        elif ffn == "moe":
            x2 = apply_norm(cfg.norm, resid, bp["norm2"])
            moe_out, aux = moe_ffn(x2, bp["ffn"], cfg, c, ep=self._ep())
            resid = resid + moe_out
        resid = c(resid, ("batch", "seq", "d_model"), "residual2")
        return resid, aux, new_cache

    def _super_block(self, resid, gparams, pattern, positions, img,
                     caches=None):
        auxes = []
        new_caches = {} if caches is not None else None
        for j, (mix, ffn) in enumerate(pattern):
            cache = caches.get(f"b{j}") if caches is not None else None
            resid, aux, nc = self._block(resid, gparams[f"b{j}"], mix, ffn,
                                         positions, img, cache)
            auxes.append(aux)
            if caches is not None:
                new_caches[f"b{j}"] = nc
        total_aux = MoEAux(
            sum(a.load_balance_loss for a in auxes),
            sum(a.router_z_loss for a in auxes),
            sum(a.dropped_fraction for a in auxes) / max(len(auxes), 1))
        return resid, total_aux, new_caches

    # -- forward -------------------------------------------------------------------
    def _backbone(self, params, resid, positions, img, caches=None):
        """Runs all layer groups; returns (resid, aux, new_caches)."""
        cfg = self.cfg
        lb = jnp.zeros(())
        zl = jnp.zeros(())
        new_caches = {} if caches is not None else None
        for gi, (pattern, repeats) in enumerate(self._groups()):
            gparams = params[f"group{gi}"]
            gcaches = caches.get(f"group{gi}") if caches is not None else None
            if repeats == 1:
                resid, aux, nc = self._super_block(
                    resid, gparams, pattern, positions, img, gcaches)
                lb, zl = lb + aux.load_balance_loss, zl + aux.router_z_loss
                if caches is not None:
                    new_caches[f"group{gi}"] = nc
                continue

            def body(carry, xs, pattern=pattern):
                r, lb_c, zl_c = carry
                if caches is not None:
                    lp, lc = xs
                else:
                    lp, lc = xs, None
                r, aux, nc = self._super_block(r, lp, pattern, positions,
                                               img, lc)
                return ((r, lb_c + aux.load_balance_loss,
                         zl_c + aux.router_z_loss), nc)

            if self.remat == "full":
                body = jax.checkpoint(body)
            elif self.remat == "dots":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            xs = (gparams, gcaches) if caches is not None else gparams
            (resid, lb, zl), scanned_caches = jax.lax.scan(
                body, (resid, lb, zl), xs)
            if caches is not None:
                new_caches[f"group{gi}"] = scanned_caches
        return resid, (lb, zl), new_caches

    def _embed(self, params, batch):
        cfg = self.cfg
        c = self.constrain
        if cfg.frontend == "audio_frames":
            resid = batch["frames"].astype(BF16)
        else:
            resid = params["embed"][batch["tokens"]].astype(BF16)
        resid = c(resid, ("batch", "seq", "d_model"), "embed_out")
        img = None
        if cfg.frontend == "vision":
            img = batch["img_embeds"].astype(BF16)
        return resid, img

    def _head(self, params, resid):
        cfg = self.cfg
        x = apply_norm(cfg.norm, resid, params["final_norm"])
        table = (params["embed"].T if cfg.tie_embeddings
                 else params["head"])
        logits = jnp.einsum("bsd,dv->bsv", x, table.astype(BF16))
        return self.constrain(logits, ("batch", "seq", "vocab"), "logits")

    def logits_fn(self, params, batch) -> jax.Array:
        """Full-sequence logits (teacher forcing) — used by tests to check
        decode-vs-parallel consistency and by the serving scorer."""
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            B, S = batch["frames"].shape[:2]
        else:
            B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        resid, img = self._embed(params, batch)
        resid, _, _ = self._backbone(params, resid, positions, img)
        return self._head(params, resid)

    def loss_fn(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        B, S = batch["labels"].shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        resid, img = self._embed(params, batch)
        resid, (lb, zl), _ = self._backbone(params, resid, positions, img)
        logits = self._head(params, resid)
        loss = cross_entropy(logits, batch["labels"])
        metrics = {"xent": loss, "aux_lb": lb, "aux_z": zl}
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, resid, batch, positions)
            metrics["mtp"] = mtp_loss
            loss = loss + MTP_WEIGHT * mtp_loss
        loss = loss + AUX_LB_WEIGHT * lb + AUX_Z_WEIGHT * zl
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, resid, batch, positions):
        """DeepSeek-V3 depth-1 multi-token prediction: combine the final
        hidden state with the embedding of the *next* token, run one extra
        block, predict token t+2 with the shared head."""
        cfg = self.cfg
        nxt = jnp.pad(batch["labels"][:, 1:], ((0, 0), (0, 1)))
        emb = params["embed"][nxt].astype(BF16)
        h = jnp.concatenate(
            [apply_norm(cfg.norm, resid, params["mtp"]["norm1"]), emb],
            axis=-1)
        h = jnp.einsum("bse,ed->bsd", h, params["mtp"]["proj"])
        out, _ = gqa_attention(h, params["mtp"]["mix"], cfg, positions,
                               self.constrain)
        h = h + out
        x2 = apply_norm(cfg.norm, h, params["mtp"]["norm2"])
        h = h + mlp(x2, params["mtp"]["ffn"], self.constrain)
        logits = self._head(params, h)
        mtp_labels = jnp.pad(batch["labels"][:, 2:], ((0, 0), (0, 2)))
        return cross_entropy(logits, mtp_labels, z_loss=0.0)

    # -- serving -------------------------------------------------------------------
    def init_caches(self, B: int, S_max: int, abstract: bool = False,
                    vector_pos: bool = False) -> dict:
        """Cache pytree (zeros) — shape source for dry-run input_specs.

        ``vector_pos=True`` makes every attention cache position a
        per-slot ``(B,)`` vector instead of a shared scalar — required by
        the continuous-batching server, where slots sit at independent
        positions (see :class:`repro.launch.scheduler.ContinuousBatcher`).
        """
        cfg = self.cfg
        caches: dict = {}
        for gi, (pattern, repeats) in enumerate(self._groups()):
            g: dict = {}
            for j, (mix, ffn) in enumerate(pattern):
                g[f"b{j}"] = self._block_cache(mix, B, S_max, repeats,
                                               abstract, vector_pos)
            caches[f"group{gi}"] = g
        return caches

    def cache_dims(self) -> dict:
        """Pytree mirroring ``init_caches`` whose leaves are logical-dim
        tuples (for plan-driven cache sharding)."""
        dims_map = {
            "kv": ("batch", "kv_seq", "kv_heads", "d_head"),
            "lat": ("batch", "kv_seq", "kv_lora"),
            "pos": (),
            "ssm_h": ("batch", "d_inner", "d_state"),
            "conv": ("batch", "d_conv", "d_inner"),
            "mC": ("batch", "heads", "d_head", "d_head2"),
            "mn": ("batch", "heads", "d_head"),
            "mm": ("batch", "heads"),
            "sl": ("batch", "d_model"),
        }
        cfg = self.cfg
        out: dict = {}
        for gi, (pattern, repeats) in enumerate(self._groups()):
            g: dict = {}
            for j, (mix, _) in enumerate(pattern):
                pre = ("layers",) if repeats > 1 else ()
                if mix in ("attn", "xattn"):
                    if cfg.mla is not None:
                        leaf = KVCache(pre + dims_map["lat"], None,
                                       pre + dims_map["pos"])
                    else:
                        leaf = KVCache(pre + dims_map["kv"],
                                       pre + dims_map["kv"],
                                       pre + dims_map["pos"])
                elif mix == "mamba":
                    leaf = (SSMState(pre + dims_map["ssm_h"]),
                            pre + dims_map["conv"])
                elif mix == "mlstm":
                    leaf = MLSTMState(pre + dims_map["mC"],
                                      pre + dims_map["mn"],
                                      pre + dims_map["mm"])
                elif mix == "slstm":
                    leaf = SLSTMState(*([pre + dims_map["sl"]] * 4))
                else:
                    leaf = None
                g[f"b{j}"] = leaf
            out[f"group{gi}"] = g
        return out

    def _block_cache(self, mix, B, S_max, repeats, abstract=False,
                     vector_pos=False):
        cfg = self.cfg
        pos_shape = (B,) if vector_pos else ()

        def z(shape, dtype=BF16):
            full = (repeats,) + shape if repeats > 1 else shape
            if abstract:
                return jax.ShapeDtypeStruct(full, dtype)
            return jnp.zeros(full, dtype)

        if mix in ("attn", "xattn"):
            if cfg.mla is not None:
                m = cfg.mla
                return KVCache(z((B, S_max, m.kv_lora + m.rope_dim)), None,
                               z(pos_shape, jnp.int32))
            KVH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
            S_eff = min(S_max, cfg.attn_window or S_max)
            # SWA caches could be ring buffers of the window; we keep the
            # full length for mask simplicity except in long_500k where
            # the window bound is what makes the cell feasible.
            S_c = S_eff if (cfg.attn_window and S_max > 65536) else S_max
            return KVCache(z((B, S_c, KVH, Dh)), z((B, S_c, KVH, Dh)),
                           z(pos_shape, jnp.int32))
        if mix == "mamba":
            mb = cfg.mamba
            Din = mb.expand * cfg.d_model
            return (SSMState(z((B, Din, mb.d_state), F32)),
                    z((B, mb.d_conv - 1, Din)))
        if mix == "mlstm":
            Din = cfg.xlstm.proj_factor_mlstm * cfg.d_model
            H = cfg.n_heads
            Dh = Din // H
            return MLSTMState(z((B, H, Dh, Dh), F32), z((B, H, Dh), F32),
                              z((B, H), F32))
        if mix == "slstm":
            D = cfg.d_model
            return SLSTMState(z((B, D), F32), z((B, D), F32),
                              z((B, D), F32), z((B, D), F32))
        return None

    def prefill(self, params, batch) -> tuple[jax.Array, dict]:
        """Full-sequence forward returning last-position logits and caches
        filled for subsequent decode."""
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            B, S = batch["frames"].shape[:2]
        else:
            B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        resid, img = self._embed(params, batch)
        resid, _, _ = self._backbone(params, resid, positions, img)
        logits = self._head(params, resid[:, -1:])
        return logits

    def decode_step(self, params, batch, caches) -> tuple[jax.Array, dict]:
        """One-token step: batch holds the current token (B,1) (or frame)
        and the position — a scalar (lock-step batch) or a per-slot
        ``(B,)`` vector (continuous batching; caches must then come from
        ``init_caches(vector_pos=True)``).

        ``batch["active"]`` (optional, ``(B,)`` bool) gates the cache
        write-back per slot: an inactive slot's caches pass through
        bit-identical to never stepping, so empty decode slots neither
        advance their position nor pollute the cache a future occupant
        will overwrite-and-mask.  Requires vector positions."""
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            B = batch["frames"].shape[0]
        else:
            B = batch["tokens"].shape[0]
        pos = batch["pos"]
        positions = (pos[:, None] if pos.ndim
                     else jnp.broadcast_to(pos, (B, 1)))
        resid, img = self._embed(params, batch)
        resid, _, new_caches = self._backbone(params, resid, positions,
                                              img, caches=caches)
        if "active" in batch:
            new_caches = self._gate_caches(batch["active"], caches,
                                           new_caches)
        logits = self._head(params, resid)
        return logits, new_caches

    def _gate_caches(self, active, old, new):
        """Per-slot select between the stepped and the previous cache
        leaves.  The batch axis of every leaf is 0, except inside a
        stacked (scanned) layer group where the leading axis is the
        layers axis — selection is applied per group so the broadcast
        shape is always right."""
        out: dict = {}
        for gi, (_pattern, repeats) in enumerate(self._groups()):
            ax = 1 if repeats > 1 else 0
            B = active.shape[0]

            def sel(o, n, ax=ax):
                shape = [1] * n.ndim
                shape[ax] = B
                return jnp.where(active.reshape(shape), n, o)

            g = f"group{gi}"
            out[g] = jax.tree.map(sel, old[g], new[g])
        return out
