"""Int8 error-feedback gradient compression for the DP sync path.

Classic EF-SGD / 1-bit-Adam style: quantize each gradient leaf to int8
with a per-leaf scale before the data-parallel all-reduce, keep the
quantization residual locally, and add it back into the next step's
gradient.  Cuts DP sync bytes 4× (f32) / 2× (bf16) with provably bounded
error accumulation (the residual feedback makes compression unbiased in
the long run).

Usage inside a shard_map'd DP sync, or around the optimizer when XLA owns
the all-reduce (compress → decompress models the wire format; the actual
byte saving on TPU comes from the shard_map variant in
``examples/compressed_dp.py``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any          # same pytree as grads, f32


def init_ef_state(grads_like: Any) -> EFState:
    return EFState(jax.tree.map(
        lambda g: (jax.ShapeDtypeStruct(g.shape, jnp.float32)
                   if isinstance(g, jax.ShapeDtypeStruct)
                   else jnp.zeros(g.shape, jnp.float32)), grads_like))


def compress(g: jax.Array, residual: jax.Array
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """g (+residual) → (int8 payload, scale, new residual)."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_residual = x - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32
               ) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress_tree(grads: Any, state: EFState
                     ) -> tuple[Any, Any, EFState]:
    """Compress every leaf; returns (payloads, scales, new EF state)."""
    out = jax.tree.map(compress, grads, state.residual)
    q = jax.tree.map(lambda o: o[0], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda o: o[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree.map(lambda o: o[2], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    return q, s, EFState(r)


def ef_decompress_tree(q: Any, s: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda qi, si: decompress(qi, si, dtype), q, s)


def dp_allreduce_compressed(grads: Any, state: EFState, axis: str
                            ) -> tuple[Any, EFState]:
    """Inside shard_map: int8 all-reduce (psum of dequantized payloads —
    on the wire int8+scale per hop in a ring; modelled here with the
    dequantized psum, which is numerically identical for a 2-hop ring)."""
    q, s, new_state = ef_compress_tree(grads, state)
    deq = ef_decompress_tree(q, s)
    summed = jax.tree.map(lambda x: jax.lax.psum(x, axis), deq)
    n = jax.lax.psum(1, axis)
    mean = jax.tree.map(lambda x: x / n, summed)
    return mean, new_state
