"""AdamW, shape-polymorphic (works on concrete arrays *and*
ShapeDtypeStruct trees so the dry-run can derive optimizer-state shapes
without allocating).

Moments default to f32; the deepseek-v3 config selects bf16 moments (the
V3 paper's low-precision recipe), halving optimizer HBM.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "f32"
    grad_clip: float = 1.0

    @property
    def _mdt(self):
        return jnp.bfloat16 if self.moment_dtype == "bf16" else jnp.float32

    def init(self, params) -> AdamWState:
        def zeros(p):
            if isinstance(p, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(p.shape, self._mdt,
                                            sharding=p.sharding)
            return jnp.zeros(p.shape, self._mdt)
        step = (jax.ShapeDtypeStruct((), jnp.int32)
                if any(isinstance(l, jax.ShapeDtypeStruct)
                       for l in jax.tree.leaves(params))
                else jnp.zeros((), jnp.int32))
        return AdamWState(step, jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params,
               lr_scale: jax.Array | float = 1.0):
        """Returns (new_params, new_state).  Update math in f32; params
        keep their storage dtype."""
        step = state.step + 1
        # Global-norm clip.
        if self.grad_clip:
            gn = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gn + 1e-9))
        else:
            scale = 1.0

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mh = m32 / c1
            vh = v32 / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.lr * lr_scale * delta
            return (new_p.astype(p.dtype), m32.astype(self._mdt),
                    v32.astype(self._mdt))

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step, new_mu, new_nu)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f
