from .adamw import AdamW, AdamWState, cosine_schedule

__all__ = ["AdamW", "AdamWState", "cosine_schedule"]
