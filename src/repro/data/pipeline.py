"""Data pipeline: deterministic synthetic corpus + host-sharded loader.

Design mirrors production loaders: each host deterministically owns a
disjoint shard of every global batch (keyed by ``(step, host_id)``), so
(a) restarts resume mid-stream bit-identically from the step index alone
(no loader checkpoint needed), (b) elastic rescaling re-partitions the
stream without duplicating or dropping samples, and (c) straggler
re-balancing can hand a slow host's shard range to another host.

The corpus is a seeded Zipf-ish token stream — markov-flavoured so the
LM loss actually decreases in the end-to-end example (pure uniform noise
would train to a flat ln(V)).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from queue import Queue
from typing import Iterator

import numpy as np


@dataclass
class SyntheticCorpus:
    vocab: int
    seed: int = 0
    zipf_a: float = 1.2

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))

    def batch(self, step: int, shard: int, batch: int, seq: int
              ) -> dict[str, np.ndarray]:
        """One shard of the global batch at ``step`` (deterministic)."""
        rng = self._rng(step, shard)
        z = rng.zipf(self.zipf_a, size=(batch, seq + 1))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        # Markov flavour: even positions partially predict the next token.
        tokens[:, 1::2] = (tokens[:, 0:-1:2] * 31 + 7) % self.vocab
        return {"tokens": tokens[:, :-1],
                "labels": np.ascontiguousarray(tokens[:, 1:])}


@dataclass
class ShardedLoader:
    """Host-local loader: yields this host's shard with background
    prefetch (double-buffered, like the TPU infeed)."""

    corpus: SyntheticCorpus
    global_batch: int
    seq: int
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        return self.corpus.batch(step, self.host_id, self.local_batch,
                                 self.seq)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        q: Queue = Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            step = 0
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    def reshard(self, n_hosts: int, host_id: int) -> "ShardedLoader":
        """Elastic re-partition: same global stream, new host layout."""
        return ShardedLoader(self.corpus, self.global_batch, self.seq,
                             n_hosts=n_hosts, host_id=host_id,
                             prefetch=self.prefetch)
