from .pipeline import SyntheticCorpus, ShardedLoader

__all__ = ["SyntheticCorpus", "ShardedLoader"]
